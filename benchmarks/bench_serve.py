"""Online-serving trajectory (BENCH_serve.json): what coalescing buys.

The serving plane (``serve/forest.py``, docs/serving.md) exists for one
measurable reason: under REQUEST traffic, per-request ``infer()`` loops
pay the full store-roundtrip + plan-lookup + dispatch cost per row,
capping throughput near 1/service-time — while micro-batch coalescing
onto the compiled-plan cache amortizes that cost across every request
in a tick WITHOUT ever re-tracing.  This bench measures both sides
honestly, open-loop:

  * OPEN-LOOP ARRIVALS — requests arrive on a fixed schedule
    (``rate_hz``), NOT as fast as the server finishes (closed loop
    hides queueing collapse: a saturated closed-loop server just slows
    its own clients).  Latency is measured from the SCHEDULED arrival
    instant, so a submitter that falls behind cannot flatter the
    server.
  * PER-REQUEST BASELINE — the decoupled-platform discipline from the
    paper's standalone lane, one request at a time: ship the row into
    the store (``store.put``), run ``engine.infer`` over it, read the
    prediction back.  The plan cache still helps it (constant [1, F]
    batch signature — we do NOT strawman the baseline with per-request
    retraces); what it cannot amortize is the per-request overhead.
    Above its capacity (~1/service-time) the open-loop queue grows and
    its percentiles collapse — that collapse is the phenomenon, not an
    artifact.
  * ZERO-RETRACE GATE — around every coalesced traffic window the
    bench snapshots the process-global ``plan.traces`` /
    ``plan.cache_misses`` counters; after ``register_model``'s bucket
    warmup BOTH deltas must be exactly 0 (every tick hits a resident
    ``CompiledQueryPlan``).  ``strict`` runs RAISE otherwise, and the
    CI serve-smoke job (``--smoke``) repeats the check plus a tail
    gate: smoke p99 must stay within ``SMOKE_P99_MULT`` of the p50
    floor — a coalescer that flushes erratically fails even when its
    median looks fine.

The acceptance line for the plane: coalesced p50 beats the
per-request baseline by >= ``MIN_MID_RATE_SPEEDUP`` at the MID arrival
rate (above baseline capacity, below coalesced capacity), with zero
retraces.  Every record field is documented in ``docs/serving.md``
(enforced by ``benchmarks/check_docs.py``).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks import common as C
from repro.core.reuse import ModelReuseCache
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore
from repro.obs import METRICS
from repro.serve.forest import ForestServeEngine
from repro.serve.router import TIER_INTERACTIVE

ALGO = "predicated"                 # jitted jnp kernel: ~0.1-1 ms/tick at
#                                     bench scale (the Pallas interpret-
#                                     mode kernels are scan-grade, not
#                                     serving-grade, on CPU)
DATASET = "fraud"                   # 28 dense features
RATES_HZ = (200, 800, 3000)         # below / above / far above the
#                                     per-request baseline's capacity
MODEL_TREES = (10, 100)             # tenant scales (both registered in
#                                     ONE engine: the runs are multi-
#                                     tenant by construction)
MIN_MID_RATE_SPEEDUP = 2.0          # acceptance: coalesced p50 wins by
#                                     >= this at the mid rate
SMOKE_P50_FLOOR_S = 2e-3            # smoke tail gate: p99 must stay
SMOKE_P99_MULT = 25.0               # within MULT x max(p50, floor)
BENCH_SERVE_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_serve.json")


def _pcts(lats_s: list[float]) -> tuple[float, float]:
    a = np.asarray(lats_s)
    return float(np.percentile(a, 50)), float(np.percentile(a, 99))


def _arrivals(rate_hz: float, n: int, t0: float) -> np.ndarray:
    """Deterministic open-loop schedule: request i is DUE at
    ``t0 + i/rate`` on the perf_counter timeline."""
    return t0 + np.arange(n) / float(rate_hz)


def run_coalesced(eng: ForestServeEngine, model: str, x: np.ndarray,
                  rate_hz: float, n: int) -> dict:
    """Drive ``n`` single-row requests at ``rate_hz`` into a RUNNING
    engine; returns latency percentiles + per-window counter deltas
    (plan.traces / plan.cache_misses deltas are THE zero-retrace
    evidence)."""
    m = eng._get(model)
    snap = {k: m.metrics.counter(k).value
            for k in ("serve.ticks", "serve.padding_rows",
                      "serve.plan_hits", "serve.plan_misses",
                      "serve.shed")}
    wh = m.metrics.histogram("serve.coalesce_width")
    w_sum, w_cnt = wh.sum, wh.count
    traces0 = METRICS.counter("plan.traces").value
    misses0 = METRICS.counter("plan.cache_misses").value

    t0 = time.perf_counter() + 0.01
    due = _arrivals(rate_hz, n, t0)
    reqs = []
    for i in range(n):
        now = time.perf_counter()
        if now < due[i]:
            time.sleep(due[i] - now)
        reqs.append(eng.submit(model, x[i % len(x)],
                               priority=TIER_INTERACTIVE))
    for r in reqs:
        r.wait(30.0)
    lats = [r.finished_at - due[i] for i, r in enumerate(reqs)]
    p50, p99 = _pcts(lats)
    span = max(r.finished_at for r in reqs) - t0
    d = {k: m.metrics.counter(k).value - v for k, v in snap.items()}
    return {
        "p50_ms": round(p50 * 1e3, 4), "p99_ms": round(p99 * 1e3, 4),
        "throughput_rps": round(n / max(span, 1e-9), 1),
        "ticks": d["serve.ticks"],
        "mean_coalesce_width": round(
            (wh.sum - w_sum) / max(wh.count - w_cnt, 1), 2),
        "padding_rows": d["serve.padding_rows"],
        "plan_hits": d["serve.plan_hits"],
        "plan_misses": d["serve.plan_misses"],
        "shed": d["serve.shed"],
        "traces_delta": METRICS.counter("plan.traces").value - traces0,
        "cache_misses_delta":
            METRICS.counter("plan.cache_misses").value - misses0,
    }


def run_baseline(forest, x: np.ndarray, rate_hz: float, n: int) -> dict:
    """Per-request ``store.put`` + ``infer`` loop on the same open-loop
    schedule (single server, FIFO — each request is served no earlier
    than its due instant, latency measured from the due instant)."""
    store = TensorBlockStore()
    eng = ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                            plan_cache=ModelReuseCache())
    store.put("req", x[:1])
    eng.infer("req", forest, algorithm=ALGO)        # warm: plan + trace
    t0 = time.perf_counter() + 0.01
    due = _arrivals(rate_hz, n, t0)
    lats = []
    for i in range(n):
        now = time.perf_counter()
        if now < due[i]:
            time.sleep(due[i] - now)
        store.put("req", np.ascontiguousarray(x[i % len(x)][None]))
        res = eng.infer("req", forest, algorithm=ALGO)
        np.asarray(res.predictions)
        lats.append(time.perf_counter() - due[i])
    p50, p99 = _pcts(lats)
    span = time.perf_counter() - t0
    return {"base_p50_ms": round(p50 * 1e3, 4),
            "base_p99_ms": round(p99 * 1e3, 4),
            "base_throughput_rps": round(n / max(span, 1e-9), 1)}


def build_engine(trees_grid=MODEL_TREES, *, buckets=(8, 32, 128),
                 interactive_deadline_s=0.002):
    """One multi-tenant engine, one registered model per tree scale
    (``forest<T>``), bucket plans warmed at registration."""
    eng = ForestServeEngine(buckets=buckets, algorithm=ALGO,
                            interactive_deadline_s=interactive_deadline_s)
    for T in trees_grid:
        eng.register_model(f"forest{T}",
                           C.get_forest(DATASET, "xgboost", T, depth=6))
    return eng


def run(rates=RATES_HZ, trees_grid=MODEL_TREES, duration_s=1.0,
        max_requests=1200, strict=True):
    """Returns (rows, records): the rate x model-scale grid, coalesced
    vs per-request, with the zero-retrace and mid-rate-speedup gates
    applied when ``strict``."""
    x, _ = C.bench_data(DATASET, scale=0.25)
    x = np.ascontiguousarray(x[:2048])
    eng = build_engine(trees_grid)
    rows, records = [], []
    mid_rate = sorted(rates)[len(rates) // 2]
    with eng:
        for T in trees_grid:
            model = f"forest{T}"
            forest = eng._get(model).forest
            for rate in rates:
                n = min(int(rate * duration_s), max_requests)
                co = run_coalesced(eng, model, x, rate, n)
                base = run_baseline(forest, x, rate, n)
                speedup = base["base_p50_ms"] / max(co["p50_ms"], 1e-9)
                rec = dict(scenario="serve", model=model, trees=T,
                           algorithm=ALGO, rate_hz=rate, requests=n,
                           duration_s=duration_s,
                           buckets=list(eng.buckets),
                           interactive_deadline_ms=round(
                               eng.interactive_deadline_s * 1e3, 3),
                           zero_retrace=bool(co["traces_delta"] == 0
                                             and co["cache_misses_delta"]
                                             == 0),
                           speedup_p50=round(speedup, 2),
                           **co, **base, **C.env_info(eng.qe.mesh))
                records.append(rec)
                rows.append({
                    "platform": f"serve-coalesced", "dataset": DATASET,
                    "model": model, "trees": T, "rate_hz": rate,
                    "load_s": 0.0, "infer_s": co["p50_ms"] / 1e3,
                    "write_s": 0.0, "total_s": co["p50_ms"] / 1e3})
                rows.append({
                    "platform": "serve-per-request", "dataset": DATASET,
                    "model": model, "trees": T, "rate_hz": rate,
                    "load_s": 0.0, "infer_s": base["base_p50_ms"] / 1e3,
                    "write_s": 0.0,
                    "total_s": base["base_p50_ms"] / 1e3})
                if strict and not rec["zero_retrace"]:
                    raise RuntimeError(
                        f"{model}@{rate}Hz re-traced after warmup: "
                        f"traces+{co['traces_delta']} "
                        f"misses+{co['cache_misses_delta']} — the bucket "
                        f"ladder leaked a new batch signature")
                if strict and rate == mid_rate \
                        and speedup < MIN_MID_RATE_SPEEDUP:
                    raise RuntimeError(
                        f"{model}@{rate}Hz coalesced p50 speedup "
                        f"{speedup:.2f}x below the "
                        f"{MIN_MID_RATE_SPEEDUP}x acceptance line")
    return rows, records


def smoke(rate_hz=800, n=300, trees=10):
    """The CI serve-smoke job: one tenant, mid arrival rate, short
    window.  RAISES on any post-warmup retrace or a p99 beyond
    ``SMOKE_P99_MULT`` x max(p50, ``SMOKE_P50_FLOOR_S``) — an erratic
    flush cadence fails even with a healthy median."""
    x, _ = C.bench_data(DATASET, scale=0.1)
    eng = build_engine((trees,))
    with eng:
        co = run_coalesced(eng, f"forest{trees}", x, rate_hz, n)
    if co["traces_delta"] != 0 or co["cache_misses_delta"] != 0:
        raise RuntimeError(
            f"serve-smoke re-traced after warmup: "
            f"traces+{co['traces_delta']} "
            f"misses+{co['cache_misses_delta']}")
    ceiling_ms = SMOKE_P99_MULT * max(co["p50_ms"], SMOKE_P50_FLOOR_S * 1e3)
    if co["p99_ms"] > ceiling_ms:
        raise RuntimeError(
            f"serve-smoke p99 {co['p99_ms']:.2f}ms beyond the tail "
            f"ceiling {ceiling_ms:.2f}ms "
            f"({SMOKE_P99_MULT}x max(p50, {SMOKE_P50_FLOOR_S * 1e3}ms))")
    print(f"# serve-smoke ok: rate={rate_hz}Hz n={n} "
          f"p50={co['p50_ms']}ms p99={co['p99_ms']}ms "
          f"width={co['mean_coalesce_width']} ticks={co['ticks']} "
          f"retraces=0")
    return co


def write_serve_json(records, path=BENCH_SERVE_JSON):
    payload = {"bench": "serve", "created_at": time.time(),
               "records": records}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: one short run, raise on retrace or "
                         "tail blowout; writes no JSON")
    ap.add_argument("--fast", action="store_true",
                    help="reduced grid (one model scale, shorter windows)")
    ap.add_argument("--duration", type=float, default=None)
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    trees = (MODEL_TREES[0],) if args.fast else MODEL_TREES
    dur = args.duration if args.duration is not None else \
        (0.4 if args.fast else 1.0)
    rows, records = run(trees_grid=trees, duration_s=dur,
                        max_requests=400 if args.fast else 1200)
    C.print_rows(rows, extra_cols=("rate_hz",))
    path = write_serve_json(records)
    for r in records:
        print(C.csv_line(
            f"serve/{r['model']}/rate{r['rate_hz']}",
            r["p50_ms"] / 1e3,
            f"speedup_p50={r['speedup_p50']}x width="
            f"{r['mean_coalesce_width']} retrace="
            f"{0 if r['zero_retrace'] else 1}"))
    print(f"# serve trajectory -> {path}")


if __name__ == "__main__":
    main()
