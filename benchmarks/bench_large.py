"""Paper Tab. 4–6: MEDIUM/LARGE dense datasets (Higgs, Airline, TPCx-AI,
row-scaled).  Claims: netsdb-udf wins small models by avoiding transfer;
netsdb-rel (model parallelism) overtakes udf as trees grow; the netsDB
advantage shrinks as inference compute starts to dominate."""

from __future__ import annotations

import argparse
import os
import tempfile

from benchmarks import common as C
from repro.core.reuse import ModelReuseCache
from repro.db import loader as ld
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore

ALGO = "predicated"


def run(datasets=("higgs", "airline", "tpcxai"), trees=C.TREE_GRID,
        model_types=("xgboost",), scale=1.0):
    rows = []
    for ds in datasets:
        x, y = C.bench_data(ds, scale=scale)
        with tempfile.TemporaryDirectory() as td:
            csv = os.path.join(td, f"{ds}.csv")
            ld.write_csv(csv, x)
            store = TensorBlockStore(default_page_rows=2048)
            store.put(ds, x)
            engine = ForestQueryEngine(store,
                                       reuse_cache=ModelReuseCache())
            for mt in model_types:
                for T in trees:
                    forest = C.get_forest(ds, mt, T)
                    base = dict(dataset=ds, model=mt, trees=T)
                    rows.append({**base,
                                 **C.run_standalone(forest, csv, "csv",
                                                    ALGO,
                                                    n_features=x.shape[1])})
                    for plan in ("udf", "rel"):
                        rows.append({**base,
                                     **C.run_netsdb(forest, store, ds,
                                                    plan, ALGO,
                                                    engine=engine)})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--datasets", default="higgs")
    args = ap.parse_args()
    trees = C.FAST_TREE_GRID if args.fast else C.TREE_GRID
    C.print_rows(run(datasets=tuple(args.datasets.split(",")),
                     trees=trees, scale=args.scale))


if __name__ == "__main__":
    main()
