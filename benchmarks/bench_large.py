"""Paper Tab. 4–6: MEDIUM/LARGE dense datasets (Higgs, Airline, TPCx-AI,
row-scaled).  Claims: netsdb-udf wins small models by avoiding transfer;
netsdb-rel (model parallelism) overtakes udf as trees grow; the netsDB
advantage shrinks as inference compute starts to dominate.

STREAMING section (``run_stream`` / BENCH_stream.json): the paper's
"large-scale datasets" scenario class — datasets that do NOT fit device
memory.  Two tier sections per dataset:

  * HOST: a dataset ≥ 4x ``device_budget_bytes`` is ingested (auto-spills
    to the host tier) and streamed through the double-buffered scan
    executor (``repro.db.executor``), for both udf and rel plans;
  * DISK: the same dataset under a host budget it also exceeds by ≥ 4x,
    so the auto cascade lands it on page-aligned mmap files and the scan
    reads memmap page views — the bottom rung of the tier ladder.

Each record reports the transfer/compute overlap fraction: the
synchronous reference pipeline (``prefetch_depth=1``) exposes the full
page-DMA wait, the double-buffered run (``prefetch_depth=2``) hides what
it can, and

    overlap_fraction = 1 - wait_streamed / wait_serial

is the hidden share.  Records also carry the ASYNC DRAIN accounting
(``drain_s`` worker write time, ``drain_wait_s`` what the compute thread
actually paid, ``drain_overlap_s`` the hidden difference — see
docs/benchmarks.md for every field and the honest XLA:CPU ≈ 0 caveats).
``run_stream`` RAISES if the budgeted ingest missed its expected tier or
if streamed predictions diverge from the all-device-resident run — the
CI ``streaming-smoke`` job runs it with ``--fast`` and deliberately tiny
device AND host budgets so out-of-core paging down to the disk tier
cannot silently regress.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

from benchmarks import common as C
from repro.core.reuse import ModelReuseCache
from repro.db import loader as ld
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore

ALGO = "predicated"
STREAM_ALGO = "predicated_pallas_fused"
BENCH_STREAM_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_stream.json")


def run(datasets=("higgs", "airline", "tpcxai"), trees=C.TREE_GRID,
        model_types=("xgboost",), scale=1.0):
    rows = []
    for ds in datasets:
        x, y = C.bench_data(ds, scale=scale)
        with tempfile.TemporaryDirectory() as td:
            csv = os.path.join(td, f"{ds}.csv")
            ld.write_csv(csv, x)
            store = TensorBlockStore(default_page_rows=2048)
            store.put(ds, x)
            engine = ForestQueryEngine(store,
                                       reuse_cache=ModelReuseCache())
            for mt in model_types:
                for T in trees:
                    forest = C.get_forest(ds, mt, T)
                    base = dict(dataset=ds, model=mt, trees=T)
                    rows.append({**base,
                                 **C.run_standalone(forest, csv, "csv",
                                                    ALGO,
                                                    n_features=x.shape[1])})
                    for plan in ("udf", "rel"):
                        rows.append({**base,
                                     **C.run_netsdb(forest, store, ds,
                                                    plan, ALGO,
                                                    engine=engine)})
    return rows


def run_stream(datasets=("higgs",), trees=C.FAST_TREE_GRID, scale=1.0,
               device_budget_bytes=None, host_budget_bytes=None,
               algo=STREAM_ALGO, page_rows=512, tiers=("host", "disk"),
               inject_drain_death=False):
    """Out-of-core streaming scan vs the all-device-resident run, per
    off-device tier (host pages, then disk mmap pages).

    Returns (rows, records).  Raises if a budgeted ingest failed to land
    on its section's tier (host section: past the device budget; disk
    section: past device AND host budgets, each exceeded >= 4x) or if
    streamed predictions diverge from the device-resident reference —
    this doubles as the CI smoke.

    ``inject_drain_death=True`` is the fault smoke (docs/reliability.md):
    each streamed run gets a ``FaultInjector`` that kills the async drain
    worker on its first item, and the run RAISES if the scan did not
    report the mid-flight fallback (``degraded_to_sync``) — parity is
    already checked, so a silent or unreported degradation cannot pass.
    """
    rows, records = [], []
    for ds in datasets:
        x, y = C.bench_data(ds, scale=scale)
        # out-of-core by construction: the dataset is >= 4x each budget
        budget = device_budget_bytes or max(x.nbytes // 4, 1)
        hbudget = host_budget_bytes or max(x.nbytes // 4, 1)
        store_dev = TensorBlockStore(default_page_rows=page_rows)
        store_dev.put(ds, x)
        engine_dev = ForestQueryEngine(store_dev,
                                       reuse_cache=ModelReuseCache(),
                                       plan_cache=ModelReuseCache())
        for tier in tiers:
            budgets = dict(device_budget_bytes=budget)
            if tier == "disk":
                budgets["host_budget_bytes"] = hbudget
            store = TensorBlockStore(default_page_rows=page_rows, **budgets)
            stored = store.put(ds, x)
            if stored.tier != tier:
                raise RuntimeError(
                    f"{ds}: ingest of {stored.nbytes} B under budgets "
                    f"{budgets} landed on tier {stored.tier!r}, expected "
                    f"{tier!r} — out-of-core spill cascade regressed")
            engine = ForestQueryEngine(store,
                                       reuse_cache=ModelReuseCache(),
                                       plan_cache=ModelReuseCache())
            for T in trees:
                forest = C.get_forest(ds, "xgboost", T)
                base = dict(dataset=ds, model="xgboost", trees=T)
                for plan in ("udf", "rel"):
                    kw = dict(algorithm=algo, plan=plan)
                    # synchronous reference first (cold compile lands
                    # here), then the double-buffered run, then the
                    # device-resident parity reference at SAME batching
                    serial = engine.infer(ds, forest, prefetch_depth=1,
                                          **kw)
                    skw = {}
                    if inject_drain_death:
                        from repro.db.faults import FaultInjector
                        skw["injector"] = FaultInjector().inject(
                            "drain_worker", fail_at=1)
                    stream = engine.infer(ds, forest, prefetch_depth=2,
                                          **kw, **skw)
                    if inject_drain_death and not (
                            stream.scan.degraded_to_sync
                            and stream.scan.faults_injected == 1):
                        raise RuntimeError(
                            f"{ds}/{plan}@{tier}: drain worker was killed "
                            f"but the scan did not report degraded_to_sync"
                            f" — unreported degradation")
                    ref = engine_dev.infer(
                        ds, forest, batch_pages=stream.scan.batch_pages,
                        **kw)
                    if not np.array_equal(np.asarray(stream.predictions),
                                          np.asarray(ref.predictions)):
                        raise RuntimeError(
                            f"{ds}/{plan}@{tier}: streamed predictions "
                            f"diverge from the device-resident run — "
                            f"parity broke")
                    sc, ss = stream.scan, serial.scan
                    overlap = max(0.0, 1.0 - sc.transfer_wait_s
                                  / max(ss.transfer_wait_s, 1e-9))
                    rows.append({**base,
                                 "platform": f"netsdb-{plan}-{tier}-stream",
                                 "load_s": 0.0,
                                 "infer_s": round(stream.infer_s
                                                  + stream.partition_s, 4),
                                 "write_s": round(stream.write_s
                                                  + stream.aggregate_s, 4),
                                 "total_s": round(stream.total_s, 4),
                                 "checksum": float(np.sum(np.asarray(
                                     stream.predictions)))})
                    records.append(dict(
                        dataset=ds, trees=T, algorithm=algo, plan=plan,
                        rows=x.shape[0], features=x.shape[1],
                        dataset_bytes=stored.nbytes,
                        device_budget_bytes=budget,
                        host_budget_bytes=(hbudget if tier == "disk"
                                           else None),
                        tier=stream.tier, out_of_core=True,
                        batch_pages=sc.batch_pages, batches=sc.batches,
                        max_in_flight=sc.max_in_flight,
                        bytes_streamed=sc.bytes_streamed,
                        transfer_wait_serial_s=round(ss.transfer_wait_s, 5),
                        transfer_wait_stream_s=round(sc.transfer_wait_s, 5),
                        overlap_fraction=round(overlap, 4),
                        compute_s=round(sc.compute_s, 5),
                        drain_s=round(sc.drain_s, 5),
                        drain_wait_s=round(sc.drain_wait_s, 5),
                        drain_overlap_s=round(sc.drain_overlap_s, 5),
                        drain_async=sc.drain_async,
                        pinned_staging=sc.pinned_staging,
                        serial_wall_s=round(ss.wall_s, 5),
                        stream_wall_s=round(sc.wall_s, 5),
                        device_wall_s=round(ref.scan.wall_s, 5),
                        **C.env_info(engine.mesh)))
    return rows, records


def write_stream_json(records, path=BENCH_STREAM_JSON):
    payload = {"bench": "out_of_core_streaming", "created_at": time.time(),
               "env": C.env_info(), "records": records}
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--datasets", default="higgs")
    ap.add_argument("--device-budget-bytes", type=int, default=None,
                    help="force this device budget for the streaming "
                         "section (default: dataset_bytes // 4)")
    ap.add_argument("--host-budget-bytes", type=int, default=None,
                    help="force this host budget for the streaming "
                         "section's DISK tier (default: "
                         "dataset_bytes // 4)")
    ap.add_argument("--stream-only", action="store_true",
                    help="skip the classic section (the CI smoke)")
    ap.add_argument("--inject-drain-death", action="store_true",
                    help="fault smoke: kill the async drain worker on "
                         "its first item in every streamed run; raise "
                         "unless the scan reports the sync fallback AND "
                         "keeps bitwise parity")
    ap.add_argument("--stream-out", default=BENCH_STREAM_JSON)
    args = ap.parse_args()
    trees = C.FAST_TREE_GRID if args.fast else C.TREE_GRID
    datasets = tuple(args.datasets.split(","))
    if not args.stream_only:
        C.print_rows(run(datasets=datasets, trees=trees, scale=args.scale))
    srows, records = run_stream(
        datasets=datasets, trees=trees,
        scale=min(args.scale, 0.25) if args.fast else args.scale,
        device_budget_bytes=args.device_budget_bytes,
        host_budget_bytes=args.host_budget_bytes,
        inject_drain_death=args.inject_drain_death)
    C.print_rows(srows, header=args.stream_only)
    if args.inject_drain_death:
        # fault smoke: don't overwrite the clean trajectory file with
        # degraded-path numbers
        print("# fault smoke OK: drain worker killed mid-scan in every "
              "streamed run; sync fallback reported, parity held")
        return
    path = write_stream_json(records, args.stream_out)
    print(f"# streaming trajectory -> {path}  (smoke OK: host AND disk "
          f"tiers executed out-of-core, parity held)")


if __name__ == "__main__":
    main()
