"""Regret grid for the cost-based optimizer (BENCH_optimizer.json).

The honesty check the optimizer ships with: over the paper's
model-scale × data-scale quadrants, measure EVERY static
(algorithm × plan) cell, then measure ``infer(plan="auto",
algorithm="auto")`` — first call (pays the decision: score + bounded
autotune) and repeat calls (must be a persisted-decision lookup feeding
the compiled-plan cache).  Per quadrant we report:

  regret_vs_best    auto steady-state wall / best static wall — the
                    gate: ≤ ``REGRET_LIMIT`` (1.25×) everywhere;
  win_vs_worst      worst static wall / auto wall — somewhere in the
                    grid this must clear ``WIN_FLOOR`` (2×): the choice
                    actually flips, picking by hand can lose big;
  autotune_reruns   ``optimizer.autotune_runs`` delta across the repeat
                    queries — must be 0 (decision cached);
  decision_hits     ``optimizer.decision_cache_hits`` delta across the
                    repeats — must be ≥ 1.

``--smoke`` is the CI optimizer-smoke job: a reduced grid with the same
assertions, raising on any violation; writes no JSON.  The full run
writes ``BENCH_optimizer.json`` (field contract: ``docs/optimizer.md``).

    PYTHONPATH=src python -m benchmarks.bench_optimizer [--smoke|--fast]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks import common as C
from repro.core.train import TrainConfig, train_forest
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore
from repro.obs import METRICS

BENCH_OPTIMIZER_JSON = os.path.join(os.path.dirname(__file__), "..",
                                    "BENCH_optimizer.json")

REGRET_LIMIT = 1.25      # auto must stay within this of the best static
WIN_FLOOR = 2.0          # and beat the worst static by this somewhere

ALGORITHMS = ("predicated", "hummingbird", "quickscorer")
PLANS = ("udf", "rel+reuse")

#: model-scale × data-scale quadrants (trees, rows); depth stays
#: moderate so the hummingbird GEMM ([B,T,L]·[L,I] against I=2^d-1)
#: scales visibly with model size without CPU-minutes per cell
GRID_TREES = (10, 120)
GRID_ROWS = (2_048, 16_384)
SMOKE_TREES = (10, 60)
SMOKE_ROWS = (2_048, 8_192)
DEPTH = 5
FEATURES = 16
PAGE_ROWS = 256


def _forest(trees: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(2_048, FEATURES)).astype(np.float32)
    y = (x[:, 0] * x[:, 1] > 0).astype(np.float32)
    return train_forest(x, y, TrainConfig(model_type="xgboost",
                                          num_trees=trees,
                                          max_depth=DEPTH, seed=seed))


def _data(rows: int, seed: int = 1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.normal(size=(rows, FEATURES)).astype(np.float32)


def _measure(engine, dataset, forest, *, iters: int, **kw) -> float:
    engine.infer(dataset, forest, **kw)          # warm: compile + caches
    return C.time_best(lambda: engine.infer(dataset, forest, **kw),
                       iters=iters)


def run_grid(trees_grid=GRID_TREES, rows_grid=GRID_ROWS, *,
             iters: int = 3, measure_budget_s: float = 8.0):
    """One record per (trees, rows) quadrant."""
    records = []
    for trees in trees_grid:
        forest = _forest(trees)
        for rows in rows_grid:
            store = TensorBlockStore(default_page_rows=PAGE_ROWS)
            store.put("grid", _data(rows))
            engine = ForestQueryEngine(store)
            engine.optimizer.measure_budget_s = measure_budget_s
            # the cells the autotune must separate are ms-scale and can
            # sit within ~1.3x of each other: extra warm probes keep one
            # scheduler hiccup from locking in the wrong cell
            engine.optimizer.probe_iters = 5
            statics = []
            for algorithm in ALGORITHMS:
                for plan in PLANS:
                    s = _measure(engine, "grid", forest, iters=iters,
                                 algorithm=algorithm, plan=plan)
                    statics.append(dict(algorithm=algorithm, plan=plan,
                                        static_s=round(s, 6)))
            best = min(statics, key=lambda r: r["static_s"])
            worst = max(statics, key=lambda r: r["static_s"])

            # first auto call: pays the decision (score + autotune)
            t0 = time.perf_counter()
            first = engine.infer("grid", forest, plan="auto",
                                 algorithm="auto")
            first_s = time.perf_counter() - t0
            dec = first.decision
            # repeat autos: persisted decision, zero autotune re-runs
            before = METRICS.counter_values()
            auto_s = C.time_best(
                lambda: engine.infer("grid", forest, plan="auto",
                                     algorithm="auto"), iters=iters)
            after = METRICS.counter_values()

            def delta(name):
                return after.get(name, 0) - before.get(name, 0)

            records.append(dict(
                trees=trees, depth=DEPTH, rows=rows, features=FEATURES,
                statics=statics,
                best_algorithm=best["algorithm"], best_plan=best["plan"],
                best_static_s=best["static_s"],
                worst_algorithm=worst["algorithm"],
                worst_plan=worst["plan"],
                worst_static_s=worst["static_s"],
                auto_algorithm=first.algorithm, auto_plan=first.plan,
                auto_s=round(auto_s, 6),
                first_auto_s=round(first_s, 6),
                decision_source=dec.source,
                cells_scored=dec.cells_scored,
                cells_measured=dec.cells_measured,
                regret_vs_best=round(auto_s / max(best["static_s"], 1e-9),
                                     4),
                win_vs_worst=round(worst["static_s"] / max(auto_s, 1e-9),
                                   4),
                autotune_reruns=delta("optimizer.autotune_runs"),
                decision_hits=delta("optimizer.decision_cache_hits"),
            ))
            # fresh stores per quadrant; drop what this one pinned
            engine.invalidate()
    return records


def check(records, *, context: str) -> None:
    """The gates — raise on any violation (used by --smoke AND the full
    run, so a published BENCH_optimizer.json can never show a losing
    auto)."""
    for r in records:
        cell = f"trees={r['trees']} rows={r['rows']}"
        if r["regret_vs_best"] > REGRET_LIMIT:
            raise RuntimeError(
                f"{context}: auto regret {r['regret_vs_best']}x > "
                f"{REGRET_LIMIT}x vs best static "
                f"({r['best_algorithm']}/{r['best_plan']}) at {cell}")
        if r["autotune_reruns"] != 0:
            raise RuntimeError(
                f"{context}: repeated infer(plan='auto') re-ran the "
                f"autotune pass {r['autotune_reruns']}x at {cell} — "
                f"decision not cached")
        if r["decision_hits"] < 1:
            raise RuntimeError(
                f"{context}: repeat auto queries never hit the decision "
                f"cache at {cell}")
    best_win = max(r["win_vs_worst"] for r in records)
    if best_win < WIN_FLOOR:
        raise RuntimeError(
            f"{context}: auto never beat the worst static cell by "
            f"{WIN_FLOOR}x (best win {best_win}x) — the choice never "
            f"flips on this grid, which defeats the optimizer's point")


def write_optimizer_json(records, path=BENCH_OPTIMIZER_JSON):
    payload = {
        "bench": "optimizer",
        "created_at": time.time(),
        "protocol": {
            "iters": "min-of-iters per cell, warm (compiled plans "
                     "resident)",
            "regret_limit": REGRET_LIMIT,
            "win_floor": WIN_FLOOR,
        },
        "env": C.env_info(),
        "records": records,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return os.path.normpath(path)


def print_records(records) -> None:
    for r in records:
        print(f"  trees={r['trees']:>4} rows={r['rows']:>6}  "
              f"auto={r['auto_algorithm']}/{r['auto_plan']}"
              f" {r['auto_s'] * 1e3:8.2f}ms  "
              f"best={r['best_algorithm']}/{r['best_plan']}"
              f" {r['best_static_s'] * 1e3:8.2f}ms  "
              f"regret={r['regret_vs_best']:.2f}x  "
              f"win_vs_worst={r['win_vs_worst']:.2f}x  "
              f"reruns={r['autotune_reruns']}")


def smoke() -> None:
    """The CI optimizer-smoke job: reduced grid, full assertions."""
    records = run_grid(SMOKE_TREES, SMOKE_ROWS, iters=2,
                       measure_budget_s=8.0)
    print_records(records)
    check(records, context="optimizer-smoke")
    print(f"# optimizer-smoke ok: {len(records)} quadrants, max regret "
          f"{max(r['regret_vs_best'] for r in records)}x, best win "
          f"{max(r['win_vs_worst'] for r in records)}x, 0 autotune "
          f"re-runs")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: reduced grid, raise on regret > "
                         f"{REGRET_LIMIT}x or autotune re-run; no JSON")
    ap.add_argument("--fast", action="store_true",
                    help="reduced grid but writes BENCH_optimizer.json")
    args = ap.parse_args()
    if args.smoke:
        smoke()
        return
    trees = SMOKE_TREES if args.fast else GRID_TREES
    rows = SMOKE_ROWS if args.fast else GRID_ROWS
    records = run_grid(trees, rows, iters=2 if args.fast else 3)
    print_records(records)
    check(records, context="bench_optimizer")
    path = write_optimizer_json(records)
    print(f"# optimizer trajectory -> {path}")


if __name__ == "__main__":
    main()
