"""Paper Fig. 8: model conversion + loading overheads.

Mapping (DESIGN.md §6.3): 'conversion' = node-list → dense-tensor layout
(complete_from_nodes) + algorithm side-tensor builds; the COMPILED
traversal's conversion cost (TreeLite/lleaves' hours of codegen) maps to
XLA jit-compile time of the unrolled select-chain graph, measured here
per algorithm.  'loading' = device_put of the converted arrays (+ the
model-reuse cache hit path, which is the paper's netsDB loading story)."""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core.algorithms import predict_raw
from repro.core.forest import (complete_from_nodes, hb_path_matrix,
                               qs_bitvectors)


def _dense_to_nodelist(forest):
    """Rebuild a sklearn-style node list from the dense layout (stand-in
    for an imported external model)."""
    T, I = forest.feature.shape
    L = forest.num_leaves
    trees = []
    fe = np.asarray(forest.feature)
    th = np.asarray(forest.threshold)
    lv = np.asarray(forest.leaf_value)
    n_nodes = 2 * I + 1
    for t in range(T):
        cl = np.full(n_nodes, -1, np.int64)
        cr = np.full(n_nodes, -1, np.int64)
        feat = np.zeros(n_nodes, np.int64)
        thr = np.zeros(n_nodes, np.float32)
        val = np.zeros(n_nodes, np.float32)
        for i in range(I):
            cl[i], cr[i] = 2 * i + 1, 2 * i + 2
            feat[i], thr[i] = fe[t, i], th[t, i]
        val[I:I + L] = lv[t]
        trees.append(dict(children_left=cl, children_right=cr,
                          feature=feat, threshold=thr, value=val))
    return trees


def run(trees_grid=(10, 500, 1600), depth=8):
    rows = []
    for T in trees_grid:
        forest = C.get_forest("higgs", "lightgbm", T, depth=depth)
        nodelist = _dense_to_nodelist(forest)

        t0 = time.perf_counter()
        f2 = complete_from_nodes(nodelist, depth=depth,
                                 n_features=forest.n_features,
                                 model_type="lightgbm")
        convert_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        hb_path_matrix(depth)
        qs_bitvectors(depth)
        aux_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        arrays = {k: jax.device_put(v) for k, v in f2.arrays().items()}
        jax.block_until_ready(arrays)
        load_s = time.perf_counter() - t0

        x = jnp.zeros((256, forest.n_features), jnp.float32)
        for algo in ("predicated", "compiled", "hummingbird",
                     "quickscorer"):
            t0 = time.perf_counter()
            fn = jax.jit(lambda xx, a=algo: predict_raw(f2, xx, a))
            jax.block_until_ready(fn(x))
            compile_s = time.perf_counter() - t0
            rows.append(dict(dataset="higgs", model="lightgbm", trees=T,
                             platform=f"convert+compile-{algo}",
                             load_s=round(load_s, 4),
                             infer_s=round(compile_s, 4),
                             write_s=round(convert_s + aux_s, 4),
                             total_s=round(load_s + compile_s + convert_s
                                           + aux_s, 4)))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trees", default="10,500,1600")
    args = ap.parse_args()
    C.print_rows(run(tuple(int(t) for t in args.trees.split(","))))


if __name__ == "__main__":
    main()
