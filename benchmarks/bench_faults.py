"""Fault-plane trajectory (BENCH_faults.json): what reliability costs.

Two claims, measured (docs/reliability.md):

  * ZERO-FAULT OVERHEAD — the instrumented scan (an ARMED FaultInjector
    on every site that never fires, plus the full RetryPolicy wrappers)
    vs the seed path with no injector at all.  The guards live in Python
    driver code strictly off the jitted hot path, so the measured
    overhead must stay within ``OVERHEAD_BOUND`` (5%) — ``run`` RAISES
    past it, which makes the bench double as the regression smoke.
  * RECOVERY LATENCY — wall time of a scan that takes a degradation
    ladder mid-flight, vs the clean run: drain-worker death -> mid-scan
    sync-drain fallback, and device-transfer retry exhaustion -> halved
    ``batch_pages`` resubmit.  Bitwise parity with the clean run is
    asserted on every recovered scan; the interesting number is how much
    wall the ladder costs, not whether the answer survives (tests pin
    that).

Timing protocol: warm once (compile), then min-of-``iters`` of the
scan's own ``wall_s`` — same shape as the rest of the trajectory
benches.  The fault runs re-arm a fresh injector every iteration so each
measured scan actually takes the ladder.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks import common as C
from repro.core.reuse import ModelReuseCache
from repro.db.faults import FAULT_SITES, FaultInjector, RetryPolicy
from repro.db.query import ForestQueryEngine
from repro.db.store import TensorBlockStore

ALGO = "predicated_pallas_fused"
OVERHEAD_BOUND = 0.05
BENCH_FAULTS_JSON = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_faults.json")


def _armed_silent_injector() -> FaultInjector:
    """Every site armed, none ever firing: the full instrumented path."""
    inj = FaultInjector()
    for site in FAULT_SITES:
        inj.inject(site, fail_at=10**9)
    return inj


def run(dataset="higgs", trees=100, scale=0.25, iters=5, plan="udf",
        batch_pages=4, page_rows=512, strict=True):
    """Returns (rows, records).  Raises (``strict``) if the zero-fault
    overhead breaches ``OVERHEAD_BOUND`` or any recovered scan loses
    bitwise parity with the clean run."""
    x, _ = C.bench_data(dataset, scale=scale)
    budget = max(x.nbytes // 4, 1)          # host tier by construction
    store = TensorBlockStore(default_page_rows=page_rows,
                             device_budget_bytes=budget)
    stored = store.put(dataset, x)
    assert stored.tier == "host", stored.tier
    engine = ForestQueryEngine(store, reuse_cache=ModelReuseCache(),
                               plan_cache=ModelReuseCache())
    forest = C.get_forest(dataset, "xgboost", trees)
    policy = RetryPolicy()
    kw = dict(algorithm=ALGO, plan=plan, batch_pages=batch_pages)
    base = dict(dataset=dataset, model="xgboost", trees=trees,
                algorithm=ALGO, plan=plan, tier=stored.tier,
                rows=x.shape[0], features=x.shape[1],
                batch_pages=batch_pages, iters=iters)

    def best(make_extra):
        walls, last = [], None
        for _ in range(iters):
            last = engine.infer(dataset, forest, **kw, **make_extra())
            walls.append(last.scan.wall_s)
        return min(walls), last

    engine.infer(dataset, forest, **kw)      # warm: compile lands here
    base_s, clean = best(dict)
    ref = np.asarray(clean.predictions)

    inst_s, inst = best(lambda: dict(injector=_armed_silent_injector(),
                                     retry_policy=policy))
    overhead = inst_s / max(base_s, 1e-9) - 1.0
    if not np.array_equal(np.asarray(inst.predictions), ref):
        raise RuntimeError("armed-but-silent injector changed predictions")
    if inst.scan.faults_injected or inst.scan.retries:
        raise RuntimeError("silent injector reported fault activity")
    if strict and overhead > OVERHEAD_BOUND:
        raise RuntimeError(
            f"zero-fault overhead {overhead:.1%} breaches the "
            f"{OVERHEAD_BOUND:.0%} bound — retry wrappers leaked onto "
            f"the hot path")
    records = [dict(scenario="zero_fault_overhead", fault_site=None,
                    baseline_wall_s=round(base_s, 5),
                    instrumented_wall_s=round(inst_s, 5),
                    recovery_wall_s=None,
                    overhead_fraction=round(overhead, 4),
                    overhead_bound=OVERHEAD_BOUND,
                    within_bound=bool(overhead <= OVERHEAD_BOUND),
                    faults_injected=0, retries=0,
                    degraded_to_sync=False, batch_resubmits=0,
                    parity=True, **base, **C.env_info(engine.mesh))]
    rows = [{**base, "platform": "faults-baseline", "load_s": 0.0,
             "infer_s": round(base_s, 4), "write_s": 0.0,
             "total_s": round(base_s, 4)},
            {**base, "platform": "faults-instrumented", "load_s": 0.0,
             "infer_s": round(inst_s, 4), "write_s": 0.0,
             "total_s": round(inst_s, 4)}]

    ladders = [
        ("recovery_drain_fallback", "drain_worker",
         lambda: FaultInjector().inject("drain_worker", fail_at=1)),
        ("recovery_batch_resubmit", "page_dma_in",
         lambda: FaultInjector().inject("page_dma_in", fail_at=1,
                                        times=policy.max_attempts)),
    ]
    for scenario, site, make_inj in ladders:
        rec_s, rec = best(lambda: dict(injector=make_inj(),
                                       retry_policy=policy))
        if not np.array_equal(np.asarray(rec.predictions), ref):
            raise RuntimeError(f"{scenario}: recovered predictions "
                               f"diverge from the clean run")
        sc = rec.scan
        if scenario == "recovery_drain_fallback" and not sc.degraded_to_sync:
            raise RuntimeError(f"{scenario}: fallback not reported")
        if scenario == "recovery_batch_resubmit" and not sc.batch_resubmits:
            raise RuntimeError(f"{scenario}: resubmit not reported")
        records.append(dict(
            scenario=scenario, fault_site=site,
            baseline_wall_s=round(base_s, 5), instrumented_wall_s=None,
            recovery_wall_s=round(rec_s, 5),
            overhead_fraction=round(rec_s / max(base_s, 1e-9) - 1.0, 4),
            overhead_bound=None, within_bound=None,
            faults_injected=sc.faults_injected, retries=sc.retries,
            degraded_to_sync=sc.degraded_to_sync,
            batch_resubmits=sc.batch_resubmits, parity=True,
            **base, **C.env_info(engine.mesh)))
        rows.append({**base, "platform": f"faults-{site}", "load_s": 0.0,
                     "infer_s": round(rec_s, 4), "write_s": 0.0,
                     "total_s": round(rec_s, 4)})
    return rows, records


def write_faults_json(records, path=BENCH_FAULTS_JSON):
    payload = {"bench": "fault_tolerance", "created_at": time.time(),
               "env": C.env_info(), "records": records}
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--trees", type=int, default=None)
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--out", default=BENCH_FAULTS_JSON)
    args = ap.parse_args()
    rows, records = run(
        trees=args.trees or (10 if args.fast else 100),
        scale=args.scale or (0.1 if args.fast else 0.25),
        iters=args.iters or (3 if args.fast else 5))
    C.print_rows(rows)
    path = write_faults_json(records, args.out)
    ov = records[0]
    print(f"# fault trajectory -> {path}  (zero-fault overhead "
          f"{ov['overhead_fraction']:+.1%}, bound {OVERHEAD_BOUND:.0%})")


if __name__ == "__main__":
    main()
