"""Generate the EXPERIMENTS.md §Roofline table from the dry-run jsonl.

    python experiments/gen_tables.py experiments/dryrun_final.jsonl
"""

import json
import sys


def main(path: str) -> None:
    recs = {}
    for line in open(path):
        r = json.loads(line)
        recs[(r["arch"], r["shape"], r["mesh"])] = r

    lines = [
        "| arch | shape | mode | compute_s | memory_s | collective_s |"
        " dominant | useful | frac | fits (args+temp GB/chip) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (arch, shape, mesh), r in sorted(recs.items()):
        if mesh != "16x16":
            continue
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | — | — | skipped |"
                         " — | — | (full-attention @500k, DESIGN §4) |")
            continue
        ma = r.get("memory_analysis") or {}
        gb = (ma.get("argument_size_in_bytes", 0)
              + ma.get("temp_size_in_bytes", 0)) / 2**30
        lines.append(
            f"| {arch} | {shape} | {r['attn_mode']} "
            f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
            f"| {r['collective_s']:.3f} | {r['dominant'].replace('_s','')} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.4f} | {gb:.1f} |")

    table = "\n".join(lines)
    # multi-pod summary
    mp = [r for r in recs.values()
          if r["mesh"] == "2x16x16" and r["status"] == "ok"]
    sp = {(r["arch"], r["shape"]): r for r in recs.values()
          if r["mesh"] == "16x16" and r["status"] == "ok"}
    ratios = []
    for r in mp:
        base = sp.get((r["arch"], r["shape"]))
        if base and base["flops_per_chip"]:
            ratios.append(r["flops_per_chip"] / base["flops_per_chip"])
    table += (f"\n\nMulti-pod (2×16×16) pass: {len(mp)} cells compiled; "
              f"mean per-chip FLOPs ratio vs single-pod = "
              f"{sum(ratios)/len(ratios):.2f} (≈0.5 ⇒ the pod axis "
              f"distributes).")

    md = open("EXPERIMENTS.md").read()
    md = md.replace("<!-- ROOFLINE_TABLE -->", table)
    open("EXPERIMENTS.md", "w").write(md)
    print(f"wrote table with {len(lines) - 2} rows")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else
         "experiments/dryrun_final.jsonl")
